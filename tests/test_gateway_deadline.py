"""Deadline semantics of the gateway's timer-driven flusher, under a
fake clock.

Regression target: the cooperative ``PricingService`` only honours
deadlines inside ``step()`` (scheduler.py — the driver must poll), so a
driver that stops polling strands queued requests forever.  The gateway
owns its own timer: a submitted request must be flushed within
``deadline_ms`` with **zero** driver calls — nothing but ``submit`` and
``result`` ever touches the gateway here.

Time is fully faked (``clock``/``sleeper`` injection): the flusher's
timer arithmetic is asserted exactly — the dispatch happens at
``t_submit + deadline``, not at some poll interval after it.
"""
import asyncio

import numpy as np
import pytest

from repro.serve.core import ChunkResult
from repro.serve.engine import PriceRequest
from repro.serve.gateway import PricingGateway
from repro.serve.scheduler import PricingService

pytestmark = pytest.mark.gateway


class FakeTime:
    """Deterministic clock: time only moves when the gateway sleeps."""

    def __init__(self):
        self.t = 0.0
        self.sleeps = []

    def clock(self) -> float:
        return self.t

    async def sleep(self, seconds: float) -> None:
        # yield once so other ready tasks run, then jump the clock —
        # the flusher's requested timeout IS the time that passes
        await asyncio.sleep(0)
        self.sleeps.append(seconds)
        self.t += seconds


class StubReplica:
    """Instant engine-free replica (this file tests *timing*, not
    prices — the oracle checks live in test_gateway_faults.py)."""

    name = "stub"

    def price_chunk(self, chunk) -> ChunkResult:
        pad = chunk.padded
        return ChunkResult(ask=np.full(pad, 2.0), bid=np.full(pad, 1.0),
                           max_pieces=0, row_pieces=np.zeros(pad, int),
                           seconds=1e-4)


def _req(s0=100.0):
    return PriceRequest(s0=s0, sigma=0.2, rate=0.1, maturity=0.25,
                        cost_rate=0.0, n_steps=8)


def test_gateway_flushes_at_deadline_with_zero_driver_calls():
    """The quote arrives, dispatched by the timer at exactly
    ``t_submit + deadline`` — the driver never polls anything."""
    fake = FakeTime()
    dispatch_times = []

    async def main():
        async with PricingGateway(
                replicas=[StubReplica()], max_batch=64, deadline_ms=50.0,
                clock=fake.clock, sleeper=fake.sleep) as gw:
            # spy on dispatch before the flusher's first iteration runs
            # (no await between start and here, so it cannot have run)
            orig = gw._dispatch_bucket
            gw._dispatch_bucket = lambda b, force=False: (
                dispatch_times.append(fake.t), orig(b, force))
            rid = await gw.submit(_req())
            quote = await gw.result(rid)
            return quote, gw.metrics()

    quote, m = asyncio.run(main())
    assert quote.ask == 2.0                       # delivered
    # the gateway has no step(): there is nothing a driver *could* poll
    assert not hasattr(PricingGateway, "step")
    assert dispatch_times == [pytest.approx(0.05)]
    assert m["deadline_flushes"] == 1
    assert m["size_flushes"] == 0


def test_deadline_batch_coalesces_all_waiting_requests():
    """Requests accumulated under the deadline flush as ONE chunk when
    the oldest request's deadline expires."""
    fake = FakeTime()

    async def main():
        async with PricingGateway(
                replicas=[StubReplica()], max_batch=64, deadline_ms=50.0,
                clock=fake.clock, sleeper=fake.sleep,
                result_cache_size=0) as gw:
            rids = [await gw.submit(_req(95.0 + i)) for i in range(3)]
            quotes = [await gw.result(r) for r in rids]
            return quotes, gw.metrics()

    quotes, m = asyncio.run(main())
    assert len(quotes) == 3
    assert m["batches"] == 1                      # one coalesced flush
    assert m["deadline_flushes"] == 1
    assert m["contracts"] == 3 and m["padded"] == 4


def test_cooperative_service_deadline_still_requires_step_polling():
    """Documents the bug the gateway fixes: the in-process service's
    deadline only fires when the driver calls step()."""
    t = [0.0]
    svc = PricingService(max_batch=64, deadline_ms=50.0,
                         clock=lambda: t[0])
    rid = svc.submit(_req())
    t[0] = 10.0                    # deadline LONG expired...
    assert svc.result(rid) is None  # ...but nothing happens without
    assert svc.pending_count == 1   # a driver step() poll
