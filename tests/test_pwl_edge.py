"""PWL edge cases: identical inputs, single-knot functions, capacity-1
batches, affine degenerates — the boundaries the fuzz tests rarely hit."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pwl as P
from repro.core import pwl_ref as R


def _eval(f, ys):
    return np.asarray(jax.vmap(lambda c: P.eval_at(f, c))(jnp.asarray(ys)))


def test_envelope_of_identical_functions_is_identity():
    ref = R.PWLRef(np.array([-1.0, 0.5]), np.array([3.0, -2.0]), -10.0, -1.0)
    f = P.from_ref(ref, 8)
    for take_max in (True, False):
        h, _ = P.envelope2(f, f, 8, take_max)
        ys = np.linspace(-4, 4, 33)
        np.testing.assert_allclose(_eval(h, ys), ref(ys), rtol=1e-12)


def test_envelope_affine_vs_affine():
    f = P.make_affine(-2.0, 1.0, 8)        # -2y + 1
    g = P.make_affine(-1.0, 0.0, 8)        # -y
    h, _ = P.envelope2(f, g, 8, take_max=True)
    ys = np.linspace(-5, 5, 41)
    want = np.maximum(-2 * ys + 1, -ys)
    np.testing.assert_allclose(_eval(h, ys), want, rtol=1e-12)
    # crossing at y = 1 becomes the single knot
    assert int(h.m) <= 2


def test_single_knot_cone_is_v():
    ref = R.PWLRef(np.array([0.5]), np.array([2.0]), -120.0, -80.0)
    v, _ = P.cone_infconv(P.from_ref(ref, 8), 120.0, 80.0, 8)
    ys = np.linspace(-3, 3, 25)
    want = R.cone_infconv(ref, 120.0, 80.0)(ys)
    np.testing.assert_allclose(_eval(v, ys), want, rtol=1e-10)


def test_overflow_reported_not_silent():
    """Force more crossings than capacity: m_raw must exceed out_cap."""
    rng = np.random.default_rng(5)
    xs = np.sort(rng.normal(0, 2, 6))
    f = R.PWLRef(xs, rng.normal(0, 50, 6), -150.0, -10.0)
    g = R.PWLRef(xs + 0.3, rng.normal(0, 50, 6), -140.0, -20.0)
    _, m_raw = P.envelope2(P.from_ref(f, 8), P.from_ref(g, 8), 2,
                           take_max=True)
    assert int(m_raw) >= 2      # raw count available for the overflow check


def test_scale_preserves_knots():
    ref = R.PWLRef(np.array([-1.0, 1.0]), np.array([5.0, 1.0]), -8.0, -1.0)
    f = P.scale(P.from_ref(ref, 8), 0.5)
    ys = np.linspace(-3, 3, 25)
    np.testing.assert_allclose(_eval(f, ys), 0.5 * ref(ys), rtol=1e-12)


def test_expense_equal_prices_is_affine():
    u = P.expense(jnp.float64(7.0), jnp.float64(-1.0), jnp.float64(100.0),
                  jnp.float64(100.0), 8)
    ys = np.linspace(-2, 2, 17)
    want = 7.0 - 100.0 * (ys - (-1.0))
    np.testing.assert_allclose(_eval(u, ys), want, rtol=1e-12)
