"""Data pipeline: determinism, resume, memmap, prefetch."""
import numpy as np

from repro.data.pipeline import (MemmapSource, Prefetcher, SyntheticSource,
                                 make_batches)


def test_synthetic_deterministic_by_step():
    s = SyntheticSource(vocab=100, global_batch=4, seq_len=16, n_micro=2)
    a = s.batch(7)
    b = s.batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = s.batch(8)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].shape == (2, 2, 16)
    # next-token targets
    np.testing.assert_array_equal(a["tokens"][..., 1:], a["targets"][..., :-1])


def test_memmap_source(tmp_path):
    data = np.arange(10_000, dtype=np.int32) % 50
    path = tmp_path / "toks.bin"
    data.tofile(path)
    s = MemmapSource(str(path), vocab=50, global_batch=2, seq_len=8)
    b0 = s.batch(0)
    assert b0["tokens"].shape == (1, 2, 8)
    np.testing.assert_array_equal(b0["tokens"].ravel()[:8], data[:8])
    # deterministic seek-by-step
    np.testing.assert_array_equal(s.batch(3)["tokens"], s.batch(3)["tokens"])


def test_prefetcher_orders_steps():
    s = SyntheticSource(vocab=100, global_batch=2, seq_len=8)
    pf = Prefetcher(s, depth=2, start_step=5)
    steps = [next(pf)[0] for _ in range(4)]
    pf.stop()
    assert steps == [5, 6, 7, 8]


def test_make_batches_resume():
    s = SyntheticSource(vocab=100, global_batch=2, seq_len=8)
    it = make_batches(s, start_step=3)
    step, b = next(it)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(b["tokens"]),
                                  s.batch(3)["tokens"])
