"""Shared test config.

NOTE: no XLA_FLAGS device-count override here — smoke tests and benches
must see exactly one (real) device.  Multi-device tests spawn subprocesses
with their own XLA_FLAGS (see test_distributed.py).
"""
import os
import sys

# pricing tests need x64; importing repro.core sets the flag before any
# other jax use in the test process.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
import repro.core  # noqa: E402,F401

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session", autouse=True)
def _shadow_guards():
    """With ``REPRO_SHADOW_GUARDS=1`` the whole session runs the serving
    stack under instrumented locks (``repro.analysis.shadow``): any write
    to a declared guarded attribute without its lock — or to an
    owner-confined attribute from a second thread — raises
    ``GuardViolation`` at the write site.  The CI gateway/procpool lanes
    set the flag; plain runs are uninstrumented."""
    if os.environ.get("REPRO_SHADOW_GUARDS") != "1":
        yield
        return
    from repro.analysis import shadow
    uninstall = shadow.install()
    try:
        yield
    finally:
        uninstall()
