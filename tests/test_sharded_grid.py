"""Device-mesh sharded scenario-grid engine vs the single-device engines.

The acceptance gate of the sharded subsystem (ISSUE 4): sharding the
flat scenario batch of ``price_grid`` / ``price_grid_rz`` / ``price_flat``
over a 1-D mesh must be *invisible* in the numbers — ask/bid surfaces,
``max_pieces`` and the OverflowError behaviour all match the
single-device call at 1e-9 for device counts {1, 2, 8}.

Two execution modes cover two CI lanes:

  * **simulated mesh** — ``devices=W`` with W beyond the process's
    device count runs the identical plan/permute/pad layout on the local
    device (``resolve_grid_mesh``); rows are independent, so this is
    bit-equal to a real mesh and runs on every push with no XLA flags;
  * **real mesh** — under ``XLA_FLAGS=--xla_force_host_platform_
    device_count=8`` (the CI ``shard`` lane) the same tests execute
    through ``shard_map`` on 8 fake devices; a ``slow``-marked
    subprocess test does the same from a clean process for the nightly
    lane.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

import jax

from repro.core.distributed import grid_mesh, resolve_grid_mesh
from repro.core.partition import plan_shards, scenario_costs
from repro.scenarios import ScenarioGrid, price_grid_notc, price_grid_rz

TOL = 1e-9
SRC = str(Path(__file__).resolve().parents[1] / "src")


@pytest.fixture(scope="module")
def mixed_grid():
    """The canonical 108-scenario mixed grid of test_scenarios.py."""
    return ScenarioGrid.cartesian(
        s0=(95.0, 105.0), sigma=(0.15, 0.25),
        cost_rate=(0.0, 0.005, 0.01),
        payoff=("put", "call", "bull_spread"),
        strike=(95.0, 100.0, 105.0),
        n_steps=10)


@pytest.fixture(scope="module")
def single_rz(mixed_grid):
    return price_grid_rz(mixed_grid, capacity=16)


# --------------------------------------------------------------------- #
# parity on the acceptance grid, device counts {1, 2, 8}
# --------------------------------------------------------------------- #
@pytest.mark.shard
@pytest.mark.parametrize("devices", [1, 2, 8])
def test_sharded_rz_parity_on_mixed_grid(mixed_grid, single_rz, devices):
    """Sharded == single-device on the 108-scenario grid at 1e-9 (ask,
    bid AND the max_pieces overflow report), for 1/2/8 shards.  Runs the
    real shard_map path when the process has enough (fake) devices, the
    bit-identical simulated layout otherwise."""
    res = price_grid_rz(mixed_grid, capacity=16, devices=devices)
    np.testing.assert_allclose(res.ask, single_rz.ask, atol=TOL)
    np.testing.assert_allclose(res.bid, single_rz.bid, atol=TOL)
    assert res.max_pieces == single_rz.max_pieces
    if devices == 1:
        assert res.shard_info is None
    else:
        info = res.shard_info
        assert info.plan.n_shards == devices
        assert sum(info.per_shard_rows) == mixed_grid.n_scenarios
        assert info.simulated == (jax.device_count() < devices)
        assert max(info.per_shard_pieces) == res.max_pieces
        # cost-model plan: uneven sizes, near-equal predicted work
        if devices == 8:
            assert len(set(info.plan.sizes)) > 1
            assert info.plan.work_spread < 0.10


@pytest.mark.shard
@pytest.mark.parametrize("devices", [2, 8])
def test_sharded_notc_parity(devices):
    grid = ScenarioGrid.cartesian(
        s0=(90.0, 100.0, 110.0), sigma=(0.2, 0.3),
        payoff=("put", "call"), strike=100.0, n_steps=12)
    want = price_grid_notc(grid)
    got = price_grid_notc(grid, devices=devices)
    np.testing.assert_allclose(got.ask, want.ask, atol=TOL)
    assert got.shard_info.plan.n_shards == devices
    # friction-free rows cost the same -> row counts split as evenly as
    # 12 rows over `devices` shards allows
    sizes = got.shard_info.plan.sizes
    assert max(sizes) - min(sizes) <= 1


@pytest.mark.shard
def test_sharded_price_flat_and_price_grid_api():
    """The api-layer entry points thread devices= through, padding
    included, with quotes matching the unsharded call."""
    from repro.api import price_flat, price_grid
    kw = dict(s0=(95.0, 100.0, 105.0, 98.0, 101.0),
              payoff=("put", "call", "put", "bull_spread", "put"),
              cost_rate=(0.0, 0.01, 0.005, 0.0, 0.01),
              strike=100.0, sigma=0.2, rate=0.1, maturity=0.25,
              n_steps=8, capacity=16, pad_to=8)
    want = price_flat(**kw)
    got = price_flat(**kw, devices=4)
    np.testing.assert_allclose(got.ask, want.ask, atol=TOL)
    np.testing.assert_allclose(got.bid, want.bid, atol=TOL)
    assert got.max_pieces == want.max_pieces
    assert got.shard_info is not None

    w2 = price_grid(s0=(95.0, 100.0), cost_rate=(0.0, 0.01), n_steps=8,
                    capacity=16)
    g2 = price_grid(s0=(95.0, 100.0), cost_rate=(0.0, 0.01), n_steps=8,
                    capacity=16, devices=2)
    np.testing.assert_allclose(g2.ask, w2.ask, atol=TOL)


@pytest.mark.shard
def test_sharded_greeks_parity():
    """FD Greeks bump the batch 5x; the shard plan must cover the bumped
    rows and the restored ordering must keep the bump blocks aligned."""
    grid = ScenarioGrid.cartesian(s0=(95.0, 105.0), cost_rate=(0.0, 0.01),
                                  payoff=("put",), strike=100.0, n_steps=8)
    want = price_grid_rz(grid, capacity=16, greeks=True)
    got = price_grid_rz(grid, capacity=16, greeks=True, devices=4)
    for f in ("ask", "bid", "delta_ask", "delta_bid", "vega_ask", "vega_bid"):
        np.testing.assert_allclose(getattr(got, f), getattr(want, f),
                                   atol=TOL, err_msg=f)
    assert got.shard_info.plan.n_rows == 5 * grid.n_scenarios


@pytest.mark.shard
def test_sharded_overflow_parity():
    """OverflowError semantics survive the gather identically: the same
    capacity that overflows single-device overflows sharded, with the
    same message shape, and nothing is silently clipped."""
    grid = ScenarioGrid.cartesian(s0=(95.0, 100.0, 105.0),
                                  cost_rate=(0.0, 0.01),
                                  payoff=("put", "call"), strike=100.0,
                                  n_steps=8)
    with pytest.raises(OverflowError, match="PWL capacity overflow"):
        price_grid_rz(grid, capacity=3)
    for devices in (2, 8):
        with pytest.raises(OverflowError, match="PWL capacity overflow"):
            price_grid_rz(grid, capacity=3, devices=devices)


@pytest.mark.shard
def test_shard_plan_validation():
    grid = ScenarioGrid.cartesian(s0=(95.0, 100.0), n_steps=8)
    bad = plan_shards(np.ones(5), 2)         # wrong row count
    with pytest.raises(ValueError, match="covers 5 rows"):
        price_grid_notc(grid, shard_plan=bad)
    with pytest.raises(ValueError, match="must be 1-D"):
        resolve_grid_mesh(mesh=_fake_2d_mesh())
    with pytest.raises(ValueError, match="devices"):
        grid_mesh(jax.device_count() + 1)


def _fake_2d_mesh():
    from jax.sharding import Mesh
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("a", "b"))


# --------------------------------------------------------------------- #
# real mesh only (the CI `shard` lane: 8 fake host devices)
# --------------------------------------------------------------------- #
@pytest.mark.shard
@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 (fake) devices; run under "
                           "XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8")
def test_real_mesh_equals_simulated_layout(mixed_grid, single_rz):
    """On a real 8-device mesh the shard_map path must agree with both
    the single-device engine and the simulated layout bit-for-bit."""
    mesh = grid_mesh(8)
    res = price_grid_rz(mixed_grid, capacity=16, mesh=mesh)
    assert not res.shard_info.simulated
    np.testing.assert_allclose(res.ask, single_rz.ask, atol=TOL)
    np.testing.assert_allclose(res.bid, single_rz.bid, atol=TOL)
    assert res.max_pieces == single_rz.max_pieces
    # identical plan executed without a mesh (simulated) is bit-equal
    sim = price_grid_rz(mixed_grid, capacity=16,
                        shard_plan=res.shard_info.plan)
    assert (np.asarray(sim.ask) == np.asarray(res.ask)).all()
    assert (np.asarray(sim.bid) == np.asarray(res.bid)).all()


# --------------------------------------------------------------------- #
# serving layer: mesh routing + measured-seconds rebalance loop
# --------------------------------------------------------------------- #
@pytest.mark.shard
def test_service_sharded_quotes_match_unsharded():
    from repro.serve.engine import PriceRequest
    from repro.serve.scheduler import PricingService

    def mk():
        return PricingService(max_batch=8, deadline_ms=0.0, capacity=16,
                              default_n_steps=8, result_cache_size=0)

    reqs = [PriceRequest(s0=90.0 + 3 * i, sigma=0.2, rate=0.1, maturity=0.25,
                         cost_rate=0.01 if i % 3 == 0 else 0.0,
                         payoff=("put", "call")[i % 2], strike=100.0,
                         n_steps=8)
            for i in range(10)]
    plain, sharded = mk(), PricingService(
        max_batch=8, deadline_ms=0.0, capacity=16, default_n_steps=8,
        result_cache_size=0, devices=4)
    ids_p = [plain.submit(r) for r in reqs]
    ids_s = [sharded.submit(r) for r in reqs]
    plain.flush(), sharded.flush()
    for rp, rs in zip(ids_p, ids_s):
        qp, qs = plain.result(rp), sharded.result(rs)
        assert qs.ask == pytest.approx(qp.ask, abs=TOL)
        assert qs.bid == pytest.approx(qp.bid, abs=TOL)
        assert qs.max_pieces == qp.max_pieces
    m = sharded.metrics()
    assert m["shard_batches"] >= 1 and m["rebalances"] >= 1
    assert plain.metrics()["shard_batches"] == 0
    # the rebalance loop produced per-device speed estimates ...
    bucket = (8, "rz")
    assert sharded.shard_speed(bucket) is not None
    # ... and the compile cache is keyed on the mesh shape (shard tuple,
    # second-to-last slot — the last is the lsmc static-config extra)
    assert any(k[-2] is not None for k in sharded._compiled)
    assert all(k[-2] is None for k in plain._compiled)


@pytest.mark.shard
def test_service_rebalance_feedback_steers_next_plan():
    """Feeding skewed per-shard seconds moves work off the slow shard on
    the next flush of the same bucket (the §4.2 reassignment loop)."""
    from repro.serve.scheduler import PricingService
    svc = PricingService(max_batch=8, deadline_ms=0.0, capacity=16,
                         default_n_steps=8, result_cache_size=0, devices=2,
                         rebalance_ema=1.0)
    bucket = (8, "notc")
    costs = scenario_costs(8, np.zeros(8), capacity=16)
    plan = svc._shard_plan(bucket, np.zeros(8), 8, 8)
    assert plan.work_spread < 1e-9
    svc.observe_shard_seconds(bucket, plan, [3.0, 1.0])
    plan2 = svc._shard_plan(bucket, np.zeros(8), 8, 8)
    assert plan2.work[0] < plan.work[0]      # slow shard shed rows
    assert svc.metrics()["rebalances"] == 1
    assert costs.shape == (8,)


# --------------------------------------------------------------------- #
# nightly: real 8-device mesh from a clean subprocess (no env leakage)
# --------------------------------------------------------------------- #
@pytest.mark.slow
@pytest.mark.shard
def test_subprocess_real_mesh_acceptance_grid():
    """The acceptance criterion end-to-end on real fake-device meshes:
    108-scenario mixed grid, device counts {1, 2, 8}, 1e-9."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    code = textwrap.dedent("""
        import numpy as np, jax
        import repro.core
        assert jax.device_count() == 8
        from repro.scenarios import ScenarioGrid, price_grid_rz
        grid = ScenarioGrid.cartesian(
            s0=(95.0, 105.0), sigma=(0.15, 0.25),
            cost_rate=(0.0, 0.005, 0.01),
            payoff=("put", "call", "bull_spread"),
            strike=(95.0, 100.0, 105.0), n_steps=10)
        want = price_grid_rz(grid, capacity=16)
        for w in (1, 2, 8):
            got = price_grid_rz(grid, capacity=16, devices=w)
            np.testing.assert_allclose(got.ask, want.ask, atol=1e-9)
            np.testing.assert_allclose(got.bid, want.bid, atol=1e-9)
            assert got.max_pieces == want.max_pieces
            if w > 1:
                assert not got.shard_info.simulated
        print("SHARD_MESH_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "SHARD_MESH_OK" in r.stdout
