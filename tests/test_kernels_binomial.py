"""Pallas binomial lattice kernel: shape/dtype sweep vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LatticeModel, american_put, price_notc_np
from repro.kernels.binomial_ref import lattice_levels_ref
from repro.kernels.binomial_step import lattice_round
from repro.kernels.ops import price_notc_kernel


@pytest.mark.parametrize("dtype,tol", [(jnp.float64, 1e-12),
                                       (jnp.float32, 1e-4)])
@pytest.mark.parametrize("block,levels,P", [
    (128, 1, 512), (128, 7, 512), (128, 64, 512),
    (64, 32, 256), (256, 100, 1024),
])
def test_round_matches_ref(dtype, tol, block, levels, P):
    if levels > block:
        pytest.skip("levels must be <= block")
    v = jax.random.uniform(jax.random.PRNGKey(0), (P,), dtype) * 50
    scalars = jnp.asarray([100.0, 0.53, 0.999, 100.0, 95.0, 0.01], dtype)
    got = lattice_round(v, scalars, levels=levels, block=block,
                        interpret=True)
    want = lattice_levels_ref(v, scalars, levels=levels)
    # all lanes except the final (boundary-clamped) block are exact
    valid = P - block
    np.testing.assert_allclose(np.asarray(got[:valid]),
                               np.asarray(want[:valid]), rtol=tol, atol=tol)


@pytest.mark.parametrize("kind", ["put", "call"])
def test_round_kind(kind):
    v = jax.random.uniform(jax.random.PRNGKey(1), (256,), jnp.float64) * 50
    scalars = jnp.asarray([60.0, 0.5, 0.999, 100.0, 95.0, 0.01], jnp.float64)
    got = lattice_round(v, scalars, levels=8, block=128, kind=kind,
                        interpret=True)
    want = lattice_levels_ref(v, scalars, levels=8, kind=kind)
    np.testing.assert_allclose(np.asarray(got[:128]), np.asarray(want[:128]),
                               rtol=1e-12)


def test_end_to_end_price_matches_oracle():
    m = LatticeModel(s0=100, sigma=0.3, rate=0.06, maturity=3.0, n_steps=300)
    got = price_notc_kernel(m, 100.0, levels=32, block=64)
    want = price_notc_np(m, american_put(100.0))
    assert abs(got - want) < 1e-10


def test_short_final_round_is_noop_protected():
    """N not a multiple of L: the kernel's lvl>=0 guard handles the tail."""
    m = LatticeModel(s0=100, sigma=0.2, rate=0.05, maturity=0.5, n_steps=123)
    got = price_notc_kernel(m, 100.0, levels=50, block=64)
    want = price_notc_np(m, american_put(100.0))
    assert abs(got - want) < 1e-10
