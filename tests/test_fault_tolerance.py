"""Elastic scaling + straggler policy (launch/elastic.py)."""
import pytest

import numpy as np

from repro.launch.elastic import (StragglerPolicy, pick_mesh, pick_topology,
                                  rescale_batch)


def test_pick_mesh_single_device():
    mesh = pick_mesh(1)
    assert mesh.shape == {"data": 1, "model": 1}


def test_pick_topology_degrades_monotonically():
    """Topology selection alone (this host has 1 device; mesh construction
    for larger topologies is exercised by the dry-run's 512 virtual
    devices)."""
    sizes = [int(np.prod(pick_topology(n)[0])) for n in (1, 2, 4, 8, 256,
                                                         512)]
    assert sizes == [1, 2, 4, 8, 256, 512]
    # a lost pod falls back from the multi-pod mesh to one pod
    assert pick_topology(511)[0] == (16, 16)
    assert pick_topology(512)[0] == (2, 16, 16)


def test_rescale_batch_preserves_global():
    out = rescale_batch(256, 4096, data_parallel=16,
                        per_device_tokens_budget=1 << 15)
    assert 256 % out["n_micro"] == 0
    per_dev_tokens = 256 // out["n_micro"] // 16 * 4096
    assert per_dev_tokens <= 1 << 15


def test_rescale_rejects_indivisible():
    with pytest.raises(AssertionError):
        rescale_batch(10, 128, data_parallel=3)


def test_straggler_policy_streaks():
    evicted = []
    pol = StragglerPolicy(factor=2.0, tolerate=2,
                          on_evict=lambda s: evicted.append(s))
    assert pol.observe(1, dt=1.0, ewma=1.0) == "ok"
    assert pol.observe(2, dt=5.0, ewma=1.0) == "tolerate"
    assert pol.observe(3, dt=5.0, ewma=1.0) == "evict"
    assert evicted == [3]
    assert pol.observe(4, dt=1.0, ewma=1.0) == "ok"
