"""CI toolchain guard: the requirements file and the optional-dep guards.

The engines depend only on jax+numpy; everything else (hypothesis,
pytest-cov, ruff) is CI toolchain installed from ``requirements-ci.txt``.
These tests pin two properties that rot silently:

  * the file keeps listing what the CI lanes invoke (a lane that
    ``pip install -r``'s a file missing its own plugin fails at runtime
    on every push);
  * the property-based suites guard their ``hypothesis`` import with
    ``pytest.importorskip``, so the tier-1 suite stays runnable in
    environments without the CI toolchain (like this container).
"""
import pathlib
import re

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _requirements() -> str:
    return (ROOT / "requirements-ci.txt").read_text()


def test_requirements_ci_lists_the_toolchain():
    req = _requirements()
    assert re.search(r"^jax\[cpu\]==", req, re.M), "jax must stay pinned"
    for pkg in ("pytest", "pytest-cov", "hypothesis", "ruff"):
        assert re.search(rf"^{re.escape(pkg)}\s*$", req, re.M), (
            f"{pkg} missing from requirements-ci.txt")


def test_hypothesis_suites_guard_their_import():
    """Every property-based module must guard its hypothesis import
    (``pytest.importorskip`` or try/except ImportError), never import it
    bare at module level — the tier-1 suite runs without it."""
    suites = sorted((ROOT / "tests").glob("*hypothesis*.py"))
    assert suites, "hypothesis suites vanished?"
    for path in suites:
        text = path.read_text()
        skip_guard = re.search(
            r'pytest\.importorskip\(\s*"hypothesis"', text)
        try_guard = re.search(
            r"try:\s*\n\s*import hypothesis\b", text)
        assert skip_guard or try_guard, (
            f"{path.name} lacks a hypothesis import guard")
        guard_pos = (skip_guard or try_guard).start()
        direct = re.search(r"^(?:from|import) hypothesis\b", text, re.M)
        assert direct is None or direct.start() > guard_pos, (
            f"{path.name} imports hypothesis before the guard")


def test_ci_workflow_invokes_what_requirements_provide():
    ci = (ROOT / ".github" / "workflows" / "ci.yml").read_text()
    # the coverage floor needs pytest-cov; the lowering lane needs the
    # registered marker (pyproject) — both are asserted here so editing
    # one file without the other fails locally, not on the runner
    assert "--cov=repro" in ci and "--cov-fail-under" in ci
    assert "-m lowering" in ci
    pyproject = (ROOT / "pyproject.toml").read_text()
    assert re.search(r'^\s*"lowering:', pyproject, re.M), (
        "lowering marker not registered in pyproject.toml")
