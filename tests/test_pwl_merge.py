"""Sort-free PWL envelope algebra: merge-path vs sort-based vs oracle.

The merge-path rewrite of ``core/pwl.py`` (``merge_sorted`` +
prefix-sum ``_compact``) must be a *drop-in* for the old
sort-with-concat engine: same knot positions, same values, same end
slopes, same raw (pre-truncation) knot counts — bit for bit.  The old
implementations are retained as ``_merge_take_bysort`` /
``_compact_bysort`` precisely so these tests can run both engines on the
same inputs.  On top of that, the traced TC hot path must contain no
``sort``/``argsort`` primitive at all (the property that unblocks a
Mosaic lowering of ``kernels/rz_step.py`` and removed the dominant cost
of the CPU hot path), and the degenerate-interval slope guard of
``_eval1``/``_slope1`` must keep coincident knots NaN-free.
"""
import contextlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import pwl as P
from repro.core import pwl_ref as R


@contextlib.contextmanager
def sort_based_engine():
    """Swap core/pwl.py back onto the pre-merge-path sort kernels.

    ``merge_sorted`` delegates to ``_merge_take`` through the module
    global, so swapping ``_merge_take`` + ``_compact`` flips every merge
    and compaction in the algebra at once.
    """
    merge, compact = P._merge_take, P._compact
    P._merge_take, P._compact = P._merge_take_bysort, P._compact_bysort
    try:
        yield
    finally:
        P._merge_take, P._compact = merge, compact


def _assert_pwl_identical(a, b, context: str):
    """Bitwise equality of two (PWL, m_raw) results (±0.0 compare equal)."""
    (fa, ma), (fb, mb) = a, b
    for xa, xb, name in zip(fa, fb, ("xs", "ys", "sl", "sr", "m")):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb),
                                      err_msg=f"{context}: {name} differs")
    assert int(ma) == int(mb), f"{context}: m_raw {int(ma)} != {int(mb)}"


# --------------------------------------------------------------------- #
# merge_sorted / _compact primitives
# --------------------------------------------------------------------- #
def test_merge_sorted_matches_sort_with_padding(rng):
    for _ in range(200):
        na, nb = int(rng.integers(1, 25)), int(rng.integers(1, 25))
        a = np.sort(rng.normal(0, 2, na))
        b = np.sort(rng.normal(0, 2, nb))
        # BIG padding tails of random length, plus injected duplicates
        a[int(rng.integers(0, na + 1)):] = P.BIG
        b[int(rng.integers(0, nb + 1)):] = P.BIG
        if na > 2:
            a[1] = a[0]                       # duplicate inside a
        if rng.random() < 0.5 and nb > 1:
            b = np.sort(np.concatenate([b[:-1], a[:1]]))  # dup across a/b
        got = np.asarray(P.merge_sorted(jnp.asarray(a), jnp.asarray(b)))
        want = np.sort(np.concatenate([a, b]))
        np.testing.assert_array_equal(got, want)


def test_merge_take_routes_payloads_with_ties(rng):
    """Payloads must follow their key element through the merge, with
    ties resolved a-first — identically in both engines (the property
    the payload-carrying envelope relies on)."""
    for _ in range(100):
        na, nb = int(rng.integers(1, 20)), int(rng.integers(1, 20))
        a = np.sort(rng.integers(0, 8, na)).astype(float)   # many ties
        b = np.sort(rng.integers(0, 8, nb)).astype(float)
        a[int(rng.integers(0, na + 1)):] = P.BIG
        b[int(rng.integers(0, nb + 1)):] = P.BIG
        pa, pb = 100.0 + np.arange(na), 200.0 + np.arange(nb)
        got = P._merge_take(jnp.asarray(a), jnp.asarray(b),
                            (jnp.asarray(pa), jnp.asarray(pb)))
        want = P._merge_take_bysort(jnp.asarray(a), jnp.asarray(b),
                                    (jnp.asarray(pa), jnp.asarray(pb)))
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
        np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
        # payload slots match their key's provenance
        key_to_payload = {**{(0, i): pa[i] for i in range(na)},
                          **{(1, j): pb[j] for j in range(nb)}}
        srcs = sorted([(a[i], 0, i) for i in range(na)]
                      + [(b[j], 1, j) for j in range(nb)])
        for k, (x, side, idx) in enumerate(srcs):
            assert float(got[0][k]) == x
            assert float(got[1][k]) == key_to_payload[(side, idx)]


def test_compact_matches_argsort_compaction(rng):
    for _ in range(200):
        n = int(rng.integers(1, 40))
        xs = np.sort(rng.normal(0, 2, n))
        xs[int(rng.integers(0, n + 1)):] = P.BIG
        ys = rng.normal(0, 50, n)
        keep = (rng.random(n) < 0.5) & (xs < P.BIG / 2)
        new = P._compact(jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(keep))
        old = P._compact_bysort(jnp.asarray(xs), jnp.asarray(ys),
                                jnp.asarray(keep))
        for a, b, name in zip(new, old, ("xs", "ys", "m")):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"_compact {name}")


# --------------------------------------------------------------------- #
# envelope / cone: merge-path == sort-based == oracle
# --------------------------------------------------------------------- #
def _random_ref(rng, max_m=6):
    m = int(rng.integers(1, max_m + 1))
    xs = np.sort(rng.normal(0, 2, m)) + np.arange(m) * 0.05
    ys = rng.normal(0, 50, m)
    sl = rng.uniform(-150, -50)
    sr = rng.uniform(-100, -10)
    return R.PWLRef(xs, ys, sl, sr)


@pytest.mark.parametrize("take_max", [True, False])
def test_envelope_merge_path_equals_sort_based(rng, take_max):
    K = 16
    for _ in range(60):
        f, g = _random_ref(rng), _random_ref(rng)
        F, G = P.from_ref(f, K), P.from_ref(g, K)
        new = P.envelope2(F, G, K, take_max)
        with sort_based_engine():
            old = P.envelope2(F, G, K, take_max)
        _assert_pwl_identical(new, old, f"envelope2(take_max={take_max})")


def test_cone_merge_path_equals_sort_based(rng):
    K = 16
    for _ in range(60):
        f = _random_ref(rng)
        a = float(rng.uniform(80, 140))
        b = float(rng.uniform(20, 70))
        f.s_left = min(f.s_left, -b - 1.0)
        f.s_right = max(f.s_right, -a)
        F = P.from_ref(f, K)
        new = P.cone_infconv(F, a, b, K)
        with sort_based_engine():
            old = P.cone_infconv(F, a, b, K)
        _assert_pwl_identical(new, old, "cone_infconv")


# --------------------------------------------------------------------- #
# jaxpr: the traced TC hot path must be sort-free
# --------------------------------------------------------------------- #
def _primitives(jaxpr, acc):
    is_leaf = lambda x: isinstance(x, (jax.core.Jaxpr, jax.core.ClosedJaxpr))
    for eqn in jaxpr.eqns:
        acc.add(eqn.primitive.name)
        for v in eqn.params.values():
            for sub in jax.tree_util.tree_leaves(v, is_leaf=is_leaf):
                if isinstance(sub, jax.core.ClosedJaxpr):
                    _primitives(sub.jaxpr, acc)
                elif isinstance(sub, jax.core.Jaxpr):
                    _primitives(sub, acc)
    return acc


def _assert_sort_free(fn, *args):
    names = _primitives(jax.make_jaxpr(fn)(*args).jaxpr, set())
    sorts = sorted(n for n in names if "sort" in n)
    assert not sorts, f"sort primitives in traced hot path: {sorts}"


def test_level_step_jaxpr_has_no_sort_primitive(rng):
    from repro.core.payoff import american_put
    from repro.core.rz import rz_level_step_lanes

    K, lanes = 12, 18
    f = P.make_affine(jnp.full((lanes,), -100.0), jnp.zeros((lanes,)), K)
    params = dict(s0=jnp.float64(100.0), k=jnp.float64(0.005),
                  sig_sqrt_dt=jnp.float64(0.01), r=jnp.float64(1.0001))
    _assert_sort_free(
        lambda z: rz_level_step_lanes(
            z, jnp.float64(16.0), params, capacity=K, seller=True,
            payoff=american_put(100.0), dtype=jnp.float64), f)


def test_envelope_and_cone_jaxprs_have_no_sort_primitive():
    K = 12
    f = P.make_affine(-100.0, 0.0, K)
    g = P.make_affine(-50.0, 1.0, K)
    _assert_sort_free(lambda a, b: P.envelope2(a, b, K, True), f, g)
    _assert_sort_free(lambda a: P.cone_infconv(a, 120.0, 80.0, K), f)


# --------------------------------------------------------------------- #
# degenerate-interval slope guard (_eval1/_slope1)
# --------------------------------------------------------------------- #
def test_eval_with_coincident_knots_is_finite():
    """Exactly duplicated knots must evaluate finite everywhere."""
    K = 8
    xs = np.full((K,), P.BIG)
    ys = np.zeros((K,))
    xs[:3] = [0.0, 0.0, 1.0]
    ys[:3] = [1.0, 2.0, 3.0]
    f = P.PWL(jnp.asarray(xs), jnp.asarray(ys),
              jnp.asarray(-2.0), jnp.asarray(0.5), jnp.asarray(3, jnp.int32))
    c = jnp.asarray([-1.0, 0.0, 0.5, 1.0, 2.0])
    v = P._eval1(f, c)
    s = P._slope1(f, c)
    assert np.all(np.isfinite(np.asarray(v)))
    assert np.all(np.isfinite(np.asarray(s)))
    # right of the duplicate pair the function is the (2, y=2)→(1, y=3)
    # segment; left of it the end slope applies
    np.testing.assert_allclose(np.asarray(v), [3.0, 2.0, 2.5, 3.0, 3.5])


def test_eval_subnormal_interval_width_no_nan():
    """The recorded blow-up: w below 1e-300 with a large value jump made
    ``dy / max(w, 1e-300)`` overflow to inf, and the query at the left
    knot then produced inf * 0 = NaN *in the selected branch* before the
    guard.  The width guard must keep it finite."""
    K = 4
    tiny_gap = 5e-324                         # subnormal: 0 < w < 1e-300
    xs = np.full((K,), P.BIG)
    ys = np.zeros((K,))
    xs[:2] = [0.0, tiny_gap]
    ys[:2] = [0.0, 1e10]
    f = P.PWL(jnp.asarray(xs), jnp.asarray(ys),
              jnp.asarray(-1.0), jnp.asarray(1.0), jnp.asarray(2, jnp.int32))
    c = jnp.asarray([0.0, -1.0, 1.0])
    v = P._eval1(f, c)
    s = P._slope1(f, c)
    assert np.all(np.isfinite(np.asarray(v))), np.asarray(v)
    assert np.all(np.isfinite(np.asarray(s))), np.asarray(s)
    # batched public surface too
    fb = jax.tree.map(lambda a: a[None], f)
    assert np.isfinite(float(P.eval_at(fb, jnp.zeros((1,)))[0]))
