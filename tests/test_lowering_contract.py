"""Lowering-contract conformance matrix (marker: ``lowering``).

Static half: every Pallas kernel's traced jaxpr must obey its declared
Mosaic/Triton compatibility contract (``repro.kernels.contracts``) — no
sort primitives, no float64/int64 under the float32 policy, only
declared dynamic-gather patterns — asserted on every platform, CPU
included, so a contract regression is caught long before a GPU/TPU lane
lowers the kernel for real.

Dynamic half: where the platform has a compiled Pallas lowering
(``supports_compiled_pallas()``), every kernel also runs
``interpret=False`` and must match the interpret oracle within its
declared per-dtype tolerance.  On CPU (jax 0.4.37:
``ValueError: Only interpret mode is supported on CPU backend.``) those
runs skip with that reason — the CPU CI lane covers the static
contracts and the interpret oracles; GPU/TPU lanes light up the real
lowerings with no test changes.
"""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core  # noqa: F401  (x64 flag side effect)
from repro.core import platform as plat
from repro.kernels import contracts as C

pytestmark = pytest.mark.lowering

SRC = pathlib.Path(__file__).resolve().parents[1] / "src"


def _cases():
    return [pytest.param(c, dt, id=f"{c.name}-{dt}")
            for c in C.CONTRACTS.values() for dt in c.dtypes]


# --------------------------------------------------------------------- #
# registry coverage: closed over the repo
# --------------------------------------------------------------------- #
def test_registry_covers_every_pallas_call_module():
    """Every module with a ``pl.pallas_call(`` site has a contract (and
    every declared contract still points at a pallas_call site) — the
    AST pass in ``repro.analysis.source_scan`` replaces the old regex
    sweep this test used to carry inline."""
    from repro.analysis import source_scan
    findings = source_scan.scan_pallas_coverage()
    assert findings == [], "\n".join(f.format() for f in findings)


def test_every_contract_declares_a_tolerance_per_dtype():
    for c in C.CONTRACTS.values():
        for dt in c.dtypes:
            assert c.tolerance(dt) > 0.0


# --------------------------------------------------------------------- #
# static contracts (run everywhere)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("contract,dtype", _cases())
def test_static_contract(contract, dtype):
    violations = C.check_static_contract(contract, dtype)
    assert not violations, f"{contract.name}@{dtype}: {violations}"


def test_walker_detects_sort():
    """Negative control: the jaxpr walker actually sees sort primitives."""
    jx = jax.make_jaxpr(jnp.sort)(jnp.zeros((8,), jnp.float32))
    prims, _ = C.jaxpr_summary(jx)
    assert prims & C.FORBIDDEN_PRIMITIVES


def test_walker_detects_weak_f64_leak():
    """Negative control: a weak-Python-float select leaks f64 at f32."""
    def leaky(x):
        return jnp.where(x > 0, 1.0, np.float64(2.0))  # f64 select
    _, dtypes = C.jaxpr_summary(jax.make_jaxpr(leaky)(
        jnp.zeros((4,), jnp.float32)))
    assert "float64" in dtypes


def test_walker_detects_int64_bookkeeping():
    """Negative control: x64-canonicalised arange shows up as int64."""
    _, dtypes = C.jaxpr_summary(jax.make_jaxpr(
        lambda x: x[jnp.arange(4)])(jnp.zeros((4,), jnp.float32)))
    assert "int64" in dtypes


# --------------------------------------------------------------------- #
# dynamic conformance: interpret oracle vs compiled lowering
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("contract,dtype", _cases())
def test_interpret_oracle_runs(contract, dtype):
    """The interpret path executes and returns finite values anywhere."""
    outs = C.run_kernel(contract, dtype, interpret=True)
    assert outs and all(np.isfinite(o).all() for o in outs)


@pytest.mark.parametrize("contract,dtype", _cases())
def test_compiled_matches_interpret(contract, dtype):
    if not plat.supports_compiled_pallas():
        pytest.skip(f"no compiled Pallas lowering on "
                    f"{plat.active_platform()} (jax: 'Only interpret "
                    "mode is supported on CPU backend.')")
    ref = C.run_kernel(contract, dtype, interpret=True)
    got = C.run_kernel(contract, dtype, interpret=False)
    tol = contract.tolerance(dtype)
    assert len(ref) == len(got)
    for r, g in zip(ref, got):
        np.testing.assert_allclose(g, r, rtol=0, atol=tol)


# --------------------------------------------------------------------- #
# platform policy resolution
# --------------------------------------------------------------------- #
def test_policy_explicit_interpret_wins_everywhere():
    for p in plat.PLATFORMS:
        assert plat.resolve_interpret(True, p) is True
        assert plat.resolve_interpret(False, p) is False


def test_policy_defaults_per_platform():
    assert plat.resolve_interpret(None, "cpu") is True
    assert plat.resolve_interpret(None, "gpu") is False
    assert plat.resolve_interpret(None, "tpu") is False
    assert not plat.supports_compiled_pallas("cpu")
    assert plat.supports_compiled_pallas("gpu")
    assert plat.supports_compiled_pallas("tpu")
    assert plat.default_dtype("cpu") == jnp.dtype("float64")
    assert plat.default_dtype("gpu") == jnp.dtype("float32")
    assert plat.xla_flags("gpu")           # the triton/latency-hiding set
    assert plat.xla_flags("cpu") == ()


def test_set_platform_policy_only_roundtrip():
    """configure_jax=False changes policy resolution, not the backend."""
    detected = plat.detect_platform()
    try:
        plat.set_platform("tpu", configure_jax=False)
        assert plat.active_platform() == "tpu"
        assert plat.resolve_interpret(None) is False
        assert plat.platform_summary()["platform"] == "tpu"
        assert plat.platform_summary()["detected"] == detected
    finally:
        plat.set_platform(None)
    assert plat.active_platform() == detected


def test_set_platform_rejects_unknown():
    with pytest.raises(ValueError, match="unknown platform"):
        plat.set_platform("quantum", configure_jax=False)
    with pytest.raises(ValueError, match="unknown platform"):
        plat.resolve_interpret(None, "cuda")


def test_platform_summary_shape():
    s = plat.platform_summary()
    assert set(s) >= {"platform", "detected", "interpret",
                      "compiled_pallas", "default_dtype", "xla_flags",
                      "jax_version"}
    assert s["platform"] in plat.PLATFORMS


def test_scheduler_compile_key_distinguishes_interpret_modes():
    """interpret and compiled programs are distinct compiled-program
    keys, while None resolves to the policy value (no phantom misses)."""
    from repro.serve.core import SchedulerCore
    core = SchedulerCore(max_batch=4)
    core.compile_key_seen(8, 10, "rz", False, interpret=True)
    core.compile_key_seen(8, 10, "rz", False, interpret=False)
    assert len(core._compiled) == 2
    # None == the platform policy's resolved value -> hits one of the two
    core.compile_key_seen(8, 10, "rz", False, interpret=None)
    assert len(core._compiled) == 2
    snap = core.metrics_.snapshot()
    assert snap["compile_hits"] == 1 and snap["compile_misses"] == 2
