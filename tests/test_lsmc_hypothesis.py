"""Property-based tests of the LSMC engine over random Bermudan schedules.

Derandomised (fixed example database seed) so the Monte Carlo asserts
inherit the determinism of the engine's per-row keys: each drawn
(schedule, seed) pair prices bit-identically on every CI run, making
the k-standard-error bounds repeatable rather than flaky.

Degeneracy properties from the contract algebra:
  * a single terminal exercise date IS a European option — the LSMC
    backward induction must reproduce the plain European MC estimate on
    the same draws *exactly* (no regression steps remain);
  * the every-step schedule IS the American contract — locked to the
    lattice oracle within standard error (plus the tree's own
    discretisation allowance);
  * fewer exercise rights are never worth more (modulo MC noise);
  * the transaction-cost premium convention preserves bid <= ask and
    collapses the spread at zero costs.
"""
import numpy as np
import pytest

from _stats import assert_within_se

pytestmark = pytest.mark.mc

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax  # noqa: E402

from repro.core import LatticeModel, american_put, price_notc_np  # noqa: E402
from repro.core.lsmc import path_keys, simulate_basket  # noqa: E402
from repro.scenarios import ScenarioGrid, price_grid_lsmc  # noqa: E402

N = 24
MKT = dict(sigma=0.2, rate=0.1, maturity=0.25)
_settings = settings(max_examples=6, deadline=None, derandomize=True)

schedules = st.sets(st.integers(1, N - 1), max_size=6).map(
    lambda s: tuple(sorted(s | {N})))


def _price(schedule, *, s0=100.0, cost_rate=0.0, paths=1024, seed=0):
    grid = ScenarioGrid.cartesian(s0=s0, cost_rate=cost_rate, strike=100.0,
                                  payoff="put", n_steps=N,
                                  exercise_steps=schedule, **MKT)
    return price_grid_lsmc(grid, n_paths=paths, seed=seed)


@given(schedules, st.sampled_from([0.0, 0.005, 0.02]))
@_settings
def test_bid_ask_ordering_under_both_cost_conventions(schedule, lam):
    res = _price(schedule, cost_rate=lam)
    ask, bid = float(res.ask.ravel()[0]), float(res.bid.ravel()[0])
    assert 0.0 <= bid <= ask
    if lam == 0.0:
        assert ask == bid          # frictionless: the spread collapses
    else:
        assert ask > bid           # premium convention: (1 +/- lam) * p


@given(st.integers(0, 5), st.sampled_from([90.0, 100.0, 110.0]))
@_settings
def test_single_terminal_date_is_european_mc(seed, s0):
    """With only the expiry exercisable there is nothing to regress:
    LSMC must equal the plain European MC estimate on the same draws."""
    res = _price((N,), s0=s0, seed=seed)
    key = np.asarray(path_keys(seed, 1))[0]
    b, t = simulate_basket(s0, MKT["sigma"], MKT["rate"], MKT["maturity"],
                           jax.numpy.asarray(key), n_steps=N, steps=(N,),
                           n_paths=1024, n_assets=1, antithetic=True)
    v = np.maximum(100.0 - np.asarray(b)[:, 0], 0.0) * np.exp(
        -MKT["rate"] * float(t[0]))
    euro = float(np.mean(0.5 * (v[:512] + v[512:])))
    assert float(res.ask.ravel()[0]) == pytest.approx(euro, abs=1e-10)


@given(st.integers(0, 5))
@_settings
def test_every_step_schedule_locks_to_american_oracle(seed):
    res = _price(tuple(range(1, N + 1)), paths=4096, seed=seed)
    oracle = price_notc_np(
        LatticeModel(s0=100.0, n_steps=N, cost_rate=0.0, **MKT),
        american_put(100.0))
    # extra: CRR discretisation gap of the N=24 oracle tree itself
    assert_within_se(res.ask.ravel()[0], oracle,
                     float(res.stderr.ravel()[0]), k=4.0, extra=0.12,
                     label=f"all-dates lsmc vs lattice (seed={seed})")


@given(schedules, st.integers(0, 3))
@_settings
def test_more_exercise_rights_never_cheaper(schedule, seed):
    sub = _price(schedule, paths=2048, seed=seed)
    dense = _price(None, paths=2048, seed=seed)
    noise = float(sub.stderr.ravel()[0]) + float(dense.stderr.ravel()[0])
    assert (float(sub.ask.ravel()[0])
            <= float(dense.ask.ravel()[0]) + 5.0 * noise)
