"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs (assignment requirement f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_archs, reduced_config
from repro.models.transformer import (RunCfg, decode_step, init_cache,
                                      init_lm, lm_loss)
from repro.optim.adamw import AdamWConfig
from repro.train.step import init_train_state, make_train_step

RUN = RunCfg(dtype=jnp.float32)
ARCHS = [a for a in list_archs()]


def _batch(cfg, key, B=2, S=32, n_micro=None):
    shape = (B, S) if n_micro is None else (n_micro, B, S)
    b = {"tokens": jax.random.randint(key, shape, 0, cfg.vocab),
         "targets": jax.random.randint(key, shape, 0, cfg.vocab)}
    if cfg.n_encoder_layers:
        if cfg.frontend == "audio_stub":
            b["enc_embeds"] = jax.random.normal(
                key, shape + (cfg.d_model,), jnp.float32)
        else:
            b["enc_tokens"] = b["tokens"]
    return b


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = reduced_config(get_config(arch))
    key = jax.random.PRNGKey(0)
    params, specs = init_lm(key, cfg)
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, tuple) and
        all(e is None or isinstance(e, str) for e in x))
    loss, metrics = jax.jit(
        lambda p, b: lm_loss(p, b, cfg, RUN))(params, _batch(cfg, key))
    assert np.isfinite(float(loss))
    assert float(metrics["loss"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = reduced_config(get_config(arch))
    key = jax.random.PRNGKey(0)
    state, _ = init_train_state(key, cfg)
    step = jax.jit(make_train_step(cfg, RUN, AdamWConfig(lr=1e-3)))
    batch = _batch(cfg, key, n_micro=2)
    new_state, metrics = step(state, batch)
    assert int(new_state.opt.step) == 1
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    delta = jax.tree.reduce(
        lambda acc, ab: acc + float(jnp.sum(jnp.abs(ab))),
        jax.tree.map(lambda a, b: a.astype(jnp.float32) -
                     b.astype(jnp.float32), new_state.params, state.params),
        0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_runs(arch):
    cfg = reduced_config(get_config(arch))
    key = jax.random.PRNGKey(0)
    params, _ = init_lm(key, cfg)
    B, S = 2, 16
    cache = init_cache(cfg, B, S, jnp.float32,
                       cross_len=S if cfg.n_encoder_layers else 0)
    logits, cache2 = jax.jit(
        lambda p, c, t, pos: decode_step(p, c, t, pos, cfg, RUN))(
        params, cache, jnp.zeros((B, 1), jnp.int32), jnp.int32(3))
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_shape_cells_defined():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}
    assert SHAPES["long_500k"].seq_len == 524_288
    # sub-quadratic archs (the only long_500k runners)
    subq = [a for a in ARCHS if get_config(a).sub_quadratic]
    assert sorted(subq) == ["falcon-mamba-7b", "recurrentgemma-2b"]
