"""Optimizer, schedules, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               clip_by_global_norm, global_norm)
from repro.optim.compression import (dequantize_int8, ef_init,
                                     quantize_int8)
from repro.optim.schedule import constant, warmup_cosine, warmup_linear


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0]), "b": jnp.array([2.0])}
    target = {"w": jnp.array([1.0, 1.0]), "b": jnp.array([0.0])}
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, clip_norm=None)
    state = adamw_init(params)

    def loss(p):
        return sum(jnp.sum((p[k] - target[k]) ** 2) for k in p)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(cfg, g, state, params)
    assert float(loss(params)) < 1e-3


def test_weight_decay_skips_1d():
    params = {"w": jnp.ones((2, 2)), "scale": jnp.ones((4,))}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5, clip_norm=None)
    state = adamw_init(params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    new, _, _ = adamw_update(cfg, zeros, state, params)
    assert float(jnp.max(jnp.abs(new["scale"] - 1.0))) < 1e-7   # no decay
    assert float(jnp.max(new["w"])) < 1.0                        # decayed


def test_clip_by_global_norm():
    tree = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert norm == pytest.approx(20.0)
    assert global_norm(clipped) == pytest.approx(1.0, rel=1e-5)


def test_schedules():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.int32(0))) == pytest.approx(0.0)
    assert float(s(jnp.int32(10))) == pytest.approx(1.0, rel=1e-5)
    assert float(s(jnp.int32(100))) == pytest.approx(0.1, rel=1e-4)
    assert float(warmup_linear(1.0, 0, 100)(jnp.int32(100))) < 1e-6
    assert float(constant(0.3)(jnp.int32(55))) == pytest.approx(0.3)


def test_int8_quantisation_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 3.0
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) / 2 + 1e-6


def test_compressed_psum_error_feedback():
    """Compressed reduction inside shard_map: mean error shrinks across
    steps thanks to error feedback (residual carried forward)."""
    from jax.sharding import PartitionSpec as PS
    from repro.optim.compression import compressed_psum

    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (64,))}

    def body(gg):
        red, ef = compressed_psum(gg, None, "data")
        red2, ef2 = compressed_psum(gg, ef, "data")
        return red, red2, ef2.residual

    from repro.compat import shard_map
    red, red2, resid = jax.jit(shard_map(
        body, mesh=mesh, in_specs=({"w": PS()},),
        out_specs=({"w": PS()}, {"w": PS()}, {"w": PS()}),
        check_vma=False))(g)
    e1 = float(jnp.max(jnp.abs(red["w"] - g["w"])))
    # with 1 participant the compressed mean == dequantised value
    assert e1 < 0.05
    # error feedback: second pass compensates the first quantisation error
    twostep = (np.asarray(red["w"]) + np.asarray(red2["w"])) / 2.0
    e2 = float(np.max(np.abs(twostep - np.asarray(g["w"]))))
    assert e2 <= e1 + 1e-6
