"""Wire-schema round trips for the chunk protocol.

The process-backed replica pool (``serve/procpool.py``) ships every
chunk across a pipe, so ``ChunkSpec``/``ChunkResult`` carry a versioned
wire form (``to_wire``/``from_wire``) of plain scalars, tuples and numpy
arrays — no live mesh objects, no callables.  This suite asserts:

* ``to_wire -> from_wire`` is the identity for every chunk shape the
  scheduler can emit — all three engines (notc / rz / lsmc), TC and
  frictionless batches, streaming row-updates, sharded ``devices=8``
  chunks (the old ``ChunkSpec.mesh`` field held a live mesh and could
  not cross a pickle boundary — the regression this file pins down);
* the wire dict survives ``pickle`` (the pipe's codec) and — for
  ``ChunkSpec`` — strict JSON, so the schema is transport-agnostic;
* the version policy: newer versions are rejected, unknown fields are
  ignored (additive evolution), missing required fields raise.
"""
import json
import pickle

import numpy as np
import pytest

from repro.serve.core import (WIRE_VERSION, ChunkResult, ChunkSpec,
                              execute_chunk)
from repro.serve.engine import PriceRequest
from repro.serve.scheduler import PricingService
from repro.serve.streaming import StreamingBook, Tick

# the wire schema is the process pool's transport contract, so this
# suite rides in the procpool CI lane (no processes are spawned here —
# the round-trips are pure data)
pytestmark = pytest.mark.procpool

N_STEPS = 8
CAPACITY = 16


def _service(**kw):
    kw.setdefault("max_batch", 64)
    kw.setdefault("capacity", CAPACITY)
    kw.setdefault("default_n_steps", N_STEPS)
    kw.setdefault("n_paths", 256)
    return PricingService(**kw)


def _req(s0=100.0, cost_rate=0.0, **kw):
    kw.setdefault("n_steps", N_STEPS)
    return PriceRequest(s0=s0, sigma=0.2, rate=0.1, maturity=0.25,
                        cost_rate=cost_rate, **kw)


def _drain_chunks(svc, reqs):
    """Submit ``reqs`` and drain every prepared chunk the scheduler
    would dispatch (exactly what a transport hands to a replica)."""
    for r in reqs:
        svc.submit(r)
    chunks = []
    for bucket in list(svc.core.buckets):
        while True:
            chunk = svc.core.take_chunk(bucket, svc.max_batch)
            if chunk is None:
                break
            svc._prepare_chunk(chunk, bucket)
            chunks.append(chunk)
    return chunks


def _scheduler_chunks():
    """One chunk per engine shape the scheduler can emit."""
    svc = _service()
    out = {}
    out["notc"] = _drain_chunks(svc, [
        _req(95.0, payoff="put", strike=100.0),
        _req(105.0, payoff="bull_spread", strike=95.0, strike2=105.0)])[0]
    out["rz"] = _drain_chunks(svc, [
        _req(98.0, cost_rate=0.01),
        _req(102.0, cost_rate=0.005, payoff="call", strike=95.0)])[0]
    out["lsmc"] = _drain_chunks(svc, [
        _req(100.0, n_assets=2),
        _req(97.0, n_assets=2, payoff="call", strike=95.0)])[0]
    out["lsmc_bermudan"] = _drain_chunks(svc, [
        _req(100.0, exercise_steps=(2, 4, N_STEPS))])[0]
    return out


def _assert_roundtrip(chunk):
    wire = chunk.to_wire()
    assert wire["version"] == WIRE_VERSION
    assert wire["kind"] == "chunk_spec"
    assert ChunkSpec.from_wire(wire) == chunk
    # the pipe's codec
    assert ChunkSpec.from_wire(pickle.loads(pickle.dumps(wire))) == chunk
    # strict JSON (tuples decay to lists; from_wire re-normalises)
    assert ChunkSpec.from_wire(json.loads(json.dumps(wire))) == chunk


@pytest.mark.parametrize("shape", ["notc", "rz", "lsmc", "lsmc_bermudan"])
def test_chunk_spec_roundtrip_every_engine_shape(shape):
    _assert_roundtrip(_scheduler_chunks()[shape])


def test_chunk_spec_pickles_whole_not_just_wire():
    """The bugfix regression: the dataclass itself (not only its wire
    form) must pickle — the old live-mesh field broke this."""
    for chunk in _scheduler_chunks().values():
        clone = pickle.loads(pickle.dumps(chunk))
        assert clone == chunk


def test_streaming_row_update_chunks_roundtrip():
    """Chunks born from streaming incremental requotes round-trip too
    (they reuse the ordinary request path, but pin it anyway)."""
    svc = _service()
    book = StreamingBook.mixed(n_underlyings=2, per_underlying=4,
                               n_steps=(N_STEPS,), capacity=CAPACITY)
    book.full_reprice()
    idx = book.apply(Tick(0, "s0", 104.0))
    chunks = _drain_chunks(svc, list(book.to_requests(idx)))
    assert chunks
    for chunk in chunks:
        _assert_roundtrip(chunk)


def test_sharded_chunk_carries_device_count_not_mesh():
    """A sharded service attaches ``devices`` (a plain int) plus the
    (pure-data) shard plan — both cross pickle and JSON untouched."""
    svc = _service(devices=8)
    chunk = _drain_chunks(svc, [_req(90.0 + i, cost_rate=0.005)
                                for i in range(4)])[0]
    assert chunk.devices == 8
    assert chunk.shard_plan is not None
    assert chunk.shard_plan.n_shards == 8
    _assert_roundtrip(chunk)
    wire = json.loads(json.dumps(chunk.to_wire()))
    assert wire["devices"] == 8          # a count, never a mesh object


@pytest.mark.parametrize("shape", ["notc", "rz", "lsmc"])
def test_chunk_result_roundtrip_every_engine(shape):
    chunk = _scheduler_chunks()[shape]
    res = execute_chunk(chunk)
    wire = res.to_wire()
    assert wire["version"] == WIRE_VERSION and wire["kind"] == "chunk_result"
    clone = ChunkResult.from_wire(pickle.loads(pickle.dumps(wire)))
    np.testing.assert_array_equal(clone.ask, res.ask)
    np.testing.assert_array_equal(clone.bid, res.bid)
    np.testing.assert_array_equal(clone.row_pieces, res.row_pieces)
    assert clone.max_pieces == res.max_pieces
    assert clone.seconds == res.seconds
    if res.stderr is not None:
        np.testing.assert_array_equal(clone.stderr, res.stderr)
    if res.shard_info is not None:
        assert clone.shard_info.plan == res.shard_info.plan


# ---------------------------------------------------------------------- #
# version / unknown-field policy
# ---------------------------------------------------------------------- #
def test_newer_version_is_rejected():
    wire = _scheduler_chunks()["notc"].to_wire()
    wire["version"] = WIRE_VERSION + 1
    with pytest.raises(ValueError, match="newer"):
        ChunkSpec.from_wire(wire)


def test_unknown_fields_are_ignored():
    """Additive evolution: an older process reads a wire dict with extra
    fields without complaint (adding a field is not a version bump)."""
    chunk = _scheduler_chunks()["rz"]
    wire = chunk.to_wire()
    wire["frobnication_level"] = 11
    assert ChunkSpec.from_wire(wire) == chunk


def test_missing_required_field_raises():
    wire = _scheduler_chunks()["notc"].to_wire()
    del wire["cols"]
    with pytest.raises(ValueError, match="cols"):
        ChunkSpec.from_wire(wire)


def test_wrong_kind_and_bad_version_raise():
    wire = _scheduler_chunks()["notc"].to_wire()
    with pytest.raises(ValueError, match="chunk_result"):
        ChunkResult.from_wire(wire)
    wire["version"] = 0
    with pytest.raises(ValueError):
        ChunkSpec.from_wire(wire)
    wire["version"] = True               # bool is not a version
    with pytest.raises(ValueError):
        ChunkSpec.from_wire(wire)
