"""Pallas linear-recurrence scan kernel: sweep vs oracle + brute force."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.lru_scan import lru_scan, lru_scan_ref


def _brute(a, b, h0):
    B, T, W = a.shape
    h = h0.copy()
    out = np.zeros_like(np.asarray(a))
    a, b = np.asarray(a), np.asarray(b)
    h = np.asarray(h0).copy()
    for t in range(T):
        h = a[:, t] * h + b[:, t]
        out[:, t] = h
    return out, h


def _make(B, T, W, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    # decay factors in (0, 1) — the RG-LRU / SSM regime
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, W)) * 2.0)
    b = jax.random.normal(ks[1], (B, T, W)) * 0.5
    h0 = jax.random.normal(ks[2], (B, W))
    return a.astype(jnp.float32), b.astype(jnp.float32), h0.astype(jnp.float32)


@pytest.mark.parametrize("B,T,W,chunk,bw", [
    (1, 64, 128, 16, 128),
    (2, 128, 256, 32, 128),
    (1, 32, 128, 32, 64),
    (2, 64, 128, 64, 128),
])
def test_kernel_vs_brute(B, T, W, chunk, bw):
    a, b, h0 = _make(B, T, W)
    h_seq, h_last = lru_scan(a, b, h0, chunk=chunk, interpret=True)
    want_seq, want_last = _brute(a, b, h0)
    np.testing.assert_allclose(np.asarray(h_seq), want_seq, rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_last), want_last, rtol=2e-5,
                               atol=2e-5)


def test_kernel_vs_model_oracle():
    a, b, h0 = _make(2, 128, 128, seed=3)
    h_seq, h_last = lru_scan(a, b, h0, chunk=32, interpret=True)
    want_seq, want_last = lru_scan_ref(a, b, h0, chunk=32)
    np.testing.assert_allclose(np.asarray(h_seq), np.asarray(want_seq),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(want_last),
                               rtol=2e-5, atol=2e-5)


def test_chunk_invariance():
    a, b, h0 = _make(1, 128, 128, seed=4)
    s1, l1 = lru_scan(a, b, h0, chunk=16, interpret=True)
    s2, l2 = lru_scan(a, b, h0, chunk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-5,
                               atol=2e-5)
