"""Layer-level correctness: flash==naive, decode==prefill consistency,
MoE gate sanity, recurrence chunking invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models import layers as L
from repro.models.transformer import (RunCfg, decode_step, init_cache,
                                      init_lm, lm_loss, prefill)

RUN = RunCfg(dtype=jnp.float32)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 32),
                                           (False, None)])
def test_flash_equals_naive(causal, window):
    key = jax.random.PRNGKey(0)
    B, T, KVH, G, hd = 2, 128, 2, 3, 16
    q = jax.random.normal(key, (B, T, KVH, G, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, KVH, hd), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, KVH, hd), jnp.float32)
    pos = jnp.arange(T)
    bias = L._mask_bias(pos, pos, causal=causal, window=window)
    want = L._attn_naive(q, k, v, bias)
    got = L._attn_flash(q, k, v, pos, pos, causal=causal, window=window,
                        q_chunk=32, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_rope_preserves_norm_and_relativity():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 8, 2, 32), jnp.float32)
    pos = jnp.arange(8)
    y = L.apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # dot products depend only on relative offset
    q = jax.random.normal(key, (1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
    def dot_at(pq, pk):
        qq = L.apply_rope(q, jnp.array([pq]), 10000.0)
        kk = L.apply_rope(k, jnp.array([pk]), 10000.0)
        return float(jnp.sum(qq * kk))
    assert dot_at(3, 1) == pytest.approx(dot_at(7, 5), abs=1e-4)


def test_moe_dense_gates_normalised():
    cfg = reduced_config(get_config("dbrx-132b"))
    key = jax.random.PRNGKey(0)
    p, _ = L.init_moe(key, cfg)
    x = jax.random.normal(key, (2, 8, cfg.d_model), jnp.float32)
    out, aux = L.moe_dense(p, x, cfg, jnp.float32)
    assert out.shape == x.shape
    assert np.isfinite(float(aux))
    # aux loss near 1.0 for a ~uniform random router (E * sum f_e p_e ~ 1)
    assert 0.5 < float(aux) < 2.5


def test_linear_scan_chunk_invariance():
    key = jax.random.PRNGKey(0)
    a = jax.nn.sigmoid(jax.random.normal(key, (2, 64, 16)))
    b = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 16))
    h0 = jnp.zeros((2, 16))
    s1, l1 = L._linear_scan_chunked(a, b, h0, 8)
    s2, l2 = L._linear_scan_chunked(a, b, h0, 64)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "recurrentgemma-2b",
                                  "falcon-mamba-7b", "qwen3-0.6b"])
def test_decode_matches_prefill_logits(arch):
    """prefill(T) then decode token T must equal prefill(T+1)'s last
    logits — KV cache / recurrent state consistency across the stack."""
    cfg = reduced_config(get_config(arch))
    key = jax.random.PRNGKey(0)
    params, _ = init_lm(key, cfg)
    B, T = 2, 16
    toks = jax.random.randint(key, (B, T + 1), 0, cfg.vocab)

    # full prefill of T+1 tokens
    full_logits, _ = prefill(params, {"tokens": toks}, cfg, RUN)
    # prefill T then decode token at position T
    _, cache = prefill(params, {"tokens": toks[:, :T]}, cfg, RUN,
                       max_len=T + 1)
    dec_logits, _ = decode_step(params, cache, toks[:, T:T + 1],
                                jnp.int32(T), cfg, RUN)
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                               np.asarray(full_logits), rtol=2e-4, atol=2e-4)


def test_encdec_decode_matches_prefill():
    cfg = reduced_config(get_config("seamless-m4t-medium"))
    key = jax.random.PRNGKey(0)
    params, _ = init_lm(key, cfg)
    B, T = 2, 12
    toks = jax.random.randint(key, (B, T + 1), 0, cfg.vocab)
    enc = jax.random.normal(jax.random.PRNGKey(9), (B, T, cfg.d_model))
    full_logits, _ = prefill(params, {"tokens": toks, "enc_embeds": enc},
                             cfg, RUN)
    _, cache = prefill(params, {"tokens": toks[:, :T], "enc_embeds": enc},
                       cfg, RUN, max_len=T + 1)
    dec_logits, _ = decode_step(params, cache, toks[:, T:T + 1],
                                jnp.int32(T), cfg, RUN)
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                               np.asarray(full_logits), rtol=2e-4, atol=2e-4)
