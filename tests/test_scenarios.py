"""Scenario-grid engine vs. the sequential per-contract oracles.

The acceptance gate of the grid subsystem: a mixed grid (payoff families
x transaction-cost rates incl. 0 x spots x vols x strikes, > 100
scenarios) priced in ONE jitted call must match pricing each contract
individually with the exact sequential recursions (``core/rz_ref.py``,
``core/notc.py::price_notc_np``) within the repo's tolerance policy
(absolute 1e-9 on prices — float64 engines vs float64 oracles).
"""
import numpy as np
import pytest

from repro.core import (LatticeModel, american_call, american_put,
                        bull_spread, price_notc_np, price_ref)
from repro.scenarios import (ScenarioGrid, price_grid_notc, price_grid_rz)

TOL = 1e-9


def _oracle_payoff(kind, k1, k2):
    if kind == "bull_spread":
        return bull_spread(k1, k2)
    return {"put": american_put, "call": american_call}[kind](k1)


def _model_of(grid, i, cost=True):
    return LatticeModel(
        s0=grid.s0[i], sigma=grid.sigma[i], rate=grid.rate[i],
        maturity=grid.maturity[i], n_steps=grid.n_steps,
        cost_rate=grid.cost_rate[i] if cost else 0.0)


@pytest.fixture(scope="module")
def big_grid():
    # 2*2*3*3*3 = 108 scenarios, one compiled call
    return ScenarioGrid.cartesian(
        s0=(95.0, 105.0), sigma=(0.15, 0.25),
        cost_rate=(0.0, 0.005, 0.01),
        payoff=("put", "call", "bull_spread"),
        strike=(95.0, 100.0, 105.0),
        n_steps=10)


@pytest.fixture(scope="module")
def big_grid_oracle(big_grid):
    """Exact sequential (ask, bid) per scenario — computed once, shared
    by the per-backend parity tests."""
    out = []
    for i in range(big_grid.n_scenarios):
        ref = price_ref(_model_of(big_grid, i),
                        _oracle_payoff(big_grid.payoff[i], big_grid.strike[i],
                                       big_grid.strike2[i]))
        out.append((ref.ask, ref.bid))
    return np.asarray(out)


def test_grid_rz_matches_sequential_oracle(big_grid, big_grid_oracle):
    grid = big_grid
    assert grid.n_scenarios >= 100
    res = price_grid_rz(grid, capacity=16)
    ask, bid = res.ask.ravel(), res.bid.ravel()
    for i in range(grid.n_scenarios):
        want_ask, want_bid = big_grid_oracle[i]
        assert ask[i] == pytest.approx(want_ask, abs=TOL), (i, grid.payoff[i])
        assert bid[i] == pytest.approx(want_bid, abs=TOL), (i, grid.payoff[i])
    assert res.max_pieces <= 16


def test_grid_rz_pallas_backend_parity(big_grid, big_grid_oracle):
    """Acceptance gate of the blocked Pallas TC engine: on the same
    108-scenario mixed grid (payoff families x lambda in {0, 0.5%, 1%} x
    spots x vols x strikes), ``backend="pallas"`` must match
    ``backend="jnp"`` AND the exact sequential oracle to 1e-9 on ask and
    bid, with identical ``max_pieces`` overflow reporting — for both the
    lambda > 0 rows and the degenerate lambda = 0 rows."""
    grid = big_grid
    res_j = price_grid_rz(grid, capacity=16)
    res_p = price_grid_rz(grid, capacity=16, backend="pallas")
    np.testing.assert_allclose(res_p.ask, res_j.ask, atol=TOL)
    np.testing.assert_allclose(res_p.bid, res_j.bid, atol=TOL)
    assert res_p.max_pieces == res_j.max_pieces
    ask, bid = res_p.ask.ravel(), res_p.bid.ravel()
    np.testing.assert_allclose(ask, big_grid_oracle[:, 0], atol=TOL)
    np.testing.assert_allclose(bid, big_grid_oracle[:, 1], atol=TOL)
    # lambda = 0 rows collapse to a point quote on the pallas path too
    lam0 = grid.cost_rate.reshape(grid.shape) == 0.0
    assert np.abs((res_p.ask - res_p.bid)[lam0]).max() < TOL
    assert (res_p.spread >= -1e-12).all()


def test_grid_rz_pallas_blocked_halo_config():
    """The multi-block (right-neighbour halo) kernel configuration, at
    grid level: small blocks force several blocks + rounds per level
    walk."""
    grid = ScenarioGrid.explicit(
        s0=(95.0, 105.0, 100.0, 100.0), sigma=0.2, rate=0.1, maturity=0.25,
        cost_rate=(0.01, 0.0, 0.005, 0.01),
        payoff=("put", "call", "bull_spread", "put"),
        strike=100.0, n_steps=12)
    res_j = price_grid_rz(grid, capacity=16)
    res_p = price_grid_rz(grid, capacity=16, backend="pallas",
                          levels=6, block=8)
    np.testing.assert_allclose(res_p.ask, res_j.ask, atol=TOL)
    np.testing.assert_allclose(res_p.bid, res_j.bid, atol=TOL)
    assert res_p.max_pieces == res_j.max_pieces


def test_grid_rz_interval_structure(big_grid):
    """bid <= ask everywhere; lambda = 0 collapses to a point quote."""
    res = price_grid_rz(big_grid, capacity=16)
    assert (res.spread >= -1e-12).all()
    lam0 = big_grid.cost_rate.reshape(big_grid.shape) == 0.0
    assert np.abs((res.ask - res.bid)[lam0]).max() < TOL


def test_grid_notc_both_backends_match_numpy_oracle():
    grid = ScenarioGrid.cartesian(
        s0=(90.0, 100.0, 110.0), sigma=(0.2, 0.3),
        payoff=("put", "call", "bull_spread"), strike=(95.0, 100.0),
        n_steps=16)
    r_jnp = price_grid_notc(grid, backend="jnp")
    r_pal = price_grid_notc(grid, backend="pallas", levels=8, block=16)
    p_jnp, p_pal = r_jnp.price.ravel(), r_pal.price.ravel()
    for i in range(grid.n_scenarios):
        want = price_notc_np(_model_of(grid, i, cost=False),
                             _oracle_payoff(grid.payoff[i], grid.strike[i],
                                            grid.strike2[i]))
        assert p_jnp[i] == pytest.approx(want, abs=TOL)
        assert p_pal[i] == pytest.approx(want, abs=TOL)


def test_grid_rz_at_lambda0_equals_notc():
    """The k = 0 TC engine and the friction-free engine agree (the
    paper's consistency anchor), now at grid level."""
    grid = ScenarioGrid.cartesian(s0=(95.0, 100.0, 105.0),
                                  payoff=("put", "call"), strike=100.0,
                                  n_steps=12)
    rz = price_grid_rz(grid, capacity=16)
    notc = price_grid_notc(grid)
    np.testing.assert_allclose(rz.ask, notc.price, atol=TOL)
    np.testing.assert_allclose(rz.bid, notc.price, atol=TOL)


def test_grid_greeks_signs_and_fd_consistency():
    grid = ScenarioGrid.explicit(
        s0=(100.0, 100.0), sigma=0.2, rate=0.1, maturity=0.25,
        cost_rate=0.005, payoff=("put", "call"), strike=(100.0, 100.0),
        n_steps=10)
    res = price_grid_rz(grid, capacity=16, greeks=True)
    put, call = 0, 1
    assert res.delta_ask[put] < 0.0 < res.delta_ask[call]
    assert res.vega_ask[put] > 0.0 and res.vega_ask[call] > 0.0
    # FD against explicitly bumped grids (same engine, separate calls)
    h = 1e-4 * 100.0
    up = price_grid_rz(ScenarioGrid.explicit(
        s0=(100.0 + h,) * 2, sigma=0.2, rate=0.1, maturity=0.25,
        cost_rate=0.005, payoff=("put", "call"), strike=(100.0, 100.0),
        n_steps=10), capacity=16)
    dn = price_grid_rz(ScenarioGrid.explicit(
        s0=(100.0 - h,) * 2, sigma=0.2, rate=0.1, maturity=0.25,
        cost_rate=0.005, payoff=("put", "call"), strike=(100.0, 100.0),
        n_steps=10), capacity=16)
    want = (up.ask - dn.ask) / (2 * h)
    np.testing.assert_allclose(res.delta_ask, want, atol=1e-9)


def test_explicit_grid_broadcasts():
    g = ScenarioGrid.explicit(s0=(90.0, 100.0, 110.0), sigma=0.2, rate=0.1,
                              maturity=0.25, cost_rate=0.01, payoff="put",
                              strike=100.0, n_steps=8)
    assert g.n_scenarios == 3 and g.shape == (3,)
    assert g.payoff == ("put",) * 3
    res = price_grid_rz(g, capacity=16)
    # puts deeper in the money are worth more
    assert res.ask[0] > res.ask[1] > res.ask[2]


def test_capacity_overflow_raises():
    g = ScenarioGrid.cartesian(s0=100.0, cost_rate=0.01,
                               payoff="bull_spread", strike=95.0,
                               strike2=105.0, n_steps=12)
    with pytest.raises(OverflowError):
        price_grid_rz(g, capacity=3)


def test_api_price_american_routes_and_matches():
    from repro.api import price_american
    q = price_american(s0=100.0, sigma=0.2, rate=0.1, maturity=0.25,
                       n_steps=12, payoff="put", strike=100.0,
                       cost_rate=0.01, capacity=16)
    ref = price_ref(LatticeModel(s0=100.0, sigma=0.2, rate=0.1,
                                 maturity=0.25, n_steps=12, cost_rate=0.01),
                    american_put(100.0))
    assert q.ask == pytest.approx(ref.ask, abs=TOL)
    assert q.bid == pytest.approx(ref.bid, abs=TOL)
    q0 = price_american(s0=100.0, sigma=0.2, rate=0.1, maturity=0.25,
                        n_steps=12, payoff="put", strike=100.0)
    assert q0.ask == q0.bid  # friction-free: point quote
    assert q.bid - TOL <= q0.ask <= q.ask + TOL


def test_api_price_grid_multi_steps():
    from repro.api import price_grid
    out = price_grid(s0=(95.0, 105.0), payoff="put", strike=100.0,
                     cost_rate=0.005, n_steps=(8, 12), capacity=16)
    assert isinstance(out, list) and len(out) == 2
    assert out[0].grid.n_steps == 8 and out[1].grid.n_steps == 12


def test_serve_engine_grid_request():
    import jax
    from repro.serve.engine import GridRequest, PricingEngine
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    eng = PricingEngine(mesh, n_steps=12, batch=4, capacity=16,
                        round_depth=4)
    req = GridRequest(s0=(95.0, 100.0, 105.0), cost_rate=(0.0, 0.01),
                      payoff=("put", "call"), strike=100.0, n_steps=12)
    res = eng.price_grid(req)
    grid = res.grid
    assert res.ask.shape == grid.shape and grid.n_scenarios == 12
    ask = res.ask.ravel()
    for i in (0, grid.n_scenarios - 1):   # spot-check against the oracle
        ref = price_ref(_model_of(grid, i),
                        _oracle_payoff(grid.payoff[i], grid.strike[i],
                                       grid.strike2[i]))
        assert ask[i] == pytest.approx(ref.ask, abs=TOL)
    assert eng.grid_stats["grids"] == 1
    assert eng.grid_stats["scenarios"] == 12
    # the serving path threads the TC backend through GridRequest
    res_p = eng.price_grid(GridRequest(
        s0=(95.0, 100.0, 105.0), cost_rate=(0.0, 0.01),
        payoff=("put", "call"), strike=100.0, n_steps=12,
        backend="pallas"))
    np.testing.assert_allclose(res_p.ask, res.ask, atol=TOL)
    np.testing.assert_allclose(res_p.bid, res.bid, atol=TOL)
    assert res_p.max_pieces == res.max_pieces
    assert eng.grid_stats["grids"] == 2
